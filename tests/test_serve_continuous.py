"""Continuous-batching serve subsystem tests (repro.serve + fleet serving).

Contracts pinned here:
* the page allocator never double-books pages, page 0 stays reserved;
* the paged decode path in models/model.py::decode_step matches the dense
  decode path logit-for-logit (and the paged int8 decode-attention kernel
  matches the dense kernel's reference within kernel-runtime tolerances);
* ContinuousBatchingEngine greedy outputs are pinned token-for-token
  against per-request ServeEngine runs — including requests admitted
  mid-flight into slots freed by retirement;
* static (EOS-masked) and continuous engines agree on EOS semantics;
* ShardedFleetServeEngine serves N chips' independent ragged streams with
  per-chip outputs identical to per-chip ContinuousBatchingEngine runs, and
  per-chip temperature sampling is reproducible and chip-independent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.fleet import ShardedFleetServeEngine
from repro.kernels.common import assert_close
from repro.kernels.decode_attention.ops import (
    decode_attention,
    paged_decode_attention,
    quantize_kv,
)
from repro.kernels.decode_attention.ref import paged_decode_attention_ref
from repro.models import model as M
from repro.serve import (
    ContinuousBatchingEngine,
    PageAllocator,
    Request,
    ServeEngine,
    dense_kv_bytes,
    page_bytes,
    pages_needed,
)
from repro.serve.kvcache import chain_layout

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served_model():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    return cfg, params


def _prompt(cfg, seed: int, length: int) -> np.ndarray:
    return np.asarray(
        jax.random.randint(jax.random.fold_in(KEY, seed), (length,), 0, cfg.vocab_size)
    )


# ---------------------------------------------------------------------------
# Page allocator + layout helpers
# ---------------------------------------------------------------------------


def test_page_allocator_freelist():
    a = PageAllocator(num_pages=6, page_size=4)
    assert a.free_pages == 5  # page 0 reserved
    p1 = a.alloc(2)
    p2 = a.alloc(1)
    assert 0 not in p1 + p2
    assert len(set(p1 + p2)) == 3
    assert a.pages_in_use == 3 and a.peak_pages == 3
    a.free(p1)
    assert a.pages_in_use == 1
    p3 = a.alloc(4)  # freed pages are reusable
    assert len(set(p2 + p3)) == 5
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.free(p3)
    with pytest.raises(ValueError):
        a.free(p3[:1])  # double free
    with pytest.raises(ValueError):
        a.free([0])  # reserved page
    with pytest.raises(ValueError):
        PageAllocator(num_pages=1, page_size=4)


def test_pages_needed_and_bytes(served_model):
    cfg, _ = served_model
    assert pages_needed(1, 8) == 1 and pages_needed(8, 8) == 1 and pages_needed(9, 8) == 2
    # one page of 8 tokens == a dense cache of batch 1 x 8 tokens
    assert page_bytes(cfg, 8) == dense_kv_bytes(cfg, 1, 8)


def test_chain_layout_roundtrip(served_model):
    cfg, _ = served_model
    L, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.arange(L * hkv * 7 * hd, dtype=jnp.float32).reshape(L, 1, hkv, 7, hd)
    chain = chain_layout(k, page_size=4, chain_len=2)  # (L, 2, Hkv, 4, hd)
    assert chain.shape == (L, 2, hkv, 4, hd)
    # tokens 0..6 land in order; slot 7 of the tail page is zero padding
    flat = jnp.moveaxis(chain, 2, 1).reshape(L, hkv, 8, hd)
    assert np.array_equal(np.asarray(flat[..., :7, :]), np.asarray(k[:, 0]))
    assert np.all(np.asarray(flat[..., 7, :]) == 0)
    with pytest.raises(ValueError):
        chain_layout(k, page_size=4, chain_len=1)


def test_init_paged_cache_rejects_unpageable():
    ssm_cfg = reduce_config(get_arch("falcon-mamba-7b"))
    with pytest.raises(ValueError, match="attention"):
        M.init_paged_cache(ssm_cfg, 8, 4, 2, 4)
    enc_cfg = reduce_config(get_arch("hubert-xlarge"))
    with pytest.raises(ValueError, match="decode"):
        M.init_paged_cache(enc_cfg, 8, 4, 2, 4)


# ---------------------------------------------------------------------------
# Paged decode path vs dense decode path (models/model.py)
# ---------------------------------------------------------------------------


def test_paged_decode_step_matches_dense(served_model):
    """Same prompts through the dense cache and through a paged cache with
    shuffled slots + an inactive lane: logits equal, greedy tokens equal,
    inactive slot's seq_len frozen."""
    cfg, params = served_model
    B, plen, page, maxp, P = 2, 8, 4, 8, 17
    prompts = jnp.stack([jnp.asarray(_prompt(cfg, 10 + b, plen)) for b in range(B)])

    logits_d, cache_d = M.prefill(params, {"tokens": prompts}, cfg, None, cache_len=32)

    cache_p = M.init_paged_cache(cfg, P, page, num_slots=3, max_pages_per_seq=maxp)
    alloc = PageAllocator(P, page)
    bt = np.zeros((3, maxp), np.int32)
    lens = np.zeros(3, np.int32)
    cur = np.zeros((B, cfg.vocab_size), np.float32)
    slot_of = [1, 2]  # slot 0 stays inactive the whole time
    for b in range(B):
        lo, c = M.prefill(params, {"tokens": prompts[b : b + 1]}, cfg, None, cache_len=plen)
        pids = alloc.alloc(pages_needed(plen + 6, page))
        cache_p["k_pages"] = cache_p["k_pages"].at[:, np.asarray(pids)].set(
            chain_layout(c["k"], page, len(pids))
        )
        cache_p["v_pages"] = cache_p["v_pages"].at[:, np.asarray(pids)].set(
            chain_layout(c["v"], page, len(pids))
        )
        bt[slot_of[b], : len(pids)] = pids
        lens[slot_of[b]] = plen
        cur[b] = np.asarray(lo[0])
    cache_p["block_tables"] = jnp.asarray(bt)
    cache_p["seq_lens"] = jnp.asarray(lens)
    np.testing.assert_allclose(cur, np.asarray(logits_d), rtol=1e-5, atol=1e-5)

    sel = jnp.asarray(slot_of)
    active = jnp.asarray([False, True, True])
    toks = jnp.argmax(logits_d, -1)
    for _ in range(5):
        ld, cache_d = M.decode_step(params, toks[:, None], cache_d, cfg, None)
        full = jnp.zeros((3,), jnp.int32).at[sel].set(toks)
        lp, cache_p = M.decode_step(params, full[:, None], cache_p, cfg, None, active=active)
        np.testing.assert_allclose(
            np.asarray(lp[:, 0][sel]), np.asarray(ld[:, 0]), rtol=2e-5, atol=2e-5
        )
        tp = jnp.argmax(lp[:, 0][sel], -1)
        toks_d = jnp.argmax(ld[:, 0], -1)
        assert np.array_equal(np.asarray(toks_d), np.asarray(tp))
        toks = toks_d
    assert int(cache_p["seq_lens"][0]) == 0  # inactive slot never advanced
    assert np.all(np.asarray(cache_p["seq_lens"][sel]) == plen + 5)


# ---------------------------------------------------------------------------
# Paged int8 decode-attention kernel (interpret mode, kernel-runtime pinning)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paged_kv_pool():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, D, page, maxp, P = 3, 4, 2, 16, 8, 4, 14
    lens = jnp.asarray([5, 17, 32], jnp.int32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, maxp * page, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, maxp * page, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
    tbl = jnp.asarray(
        rng.permutation(np.arange(1, P))[: B * maxp].reshape(B, maxp), jnp.int32
    )
    ki8, ks = quantize_kv(k)
    vi8, vs = quantize_kv(v)
    pool_k = jnp.zeros((Hkv, P, page, D), jnp.int8)
    pool_ks = jnp.zeros((Hkv, P, page), jnp.float32)
    pool_v = jnp.zeros((Hkv, P, page, D), jnp.int8)
    pool_vs = jnp.zeros((Hkv, P, page), jnp.float32)
    for b in range(B):
        for i in range(maxp):
            pid, sl = int(tbl[b, i]), slice(i * page, (i + 1) * page)
            pool_k = pool_k.at[:, pid].set(ki8[b, :, sl])
            pool_ks = pool_ks.at[:, pid].set(ks[b, :, sl])
            pool_v = pool_v.at[:, pid].set(vi8[b, :, sl])
            pool_vs = pool_vs.at[:, pid].set(vs[b, :, sl])
    return q, (ki8, ks, vi8, vs), (pool_k, pool_ks, pool_v, pool_vs), tbl, lens


def test_paged_ref_matches_dense_ref_per_sequence(paged_kv_pool):
    q, dense, pool, tbl, lens = paged_kv_pool
    ki8, ks, vi8, vs = dense
    ref = paged_decode_attention_ref(q, *pool, tbl, lens)
    for b in range(q.shape[0]):
        d = decode_attention(
            q[b : b + 1], ki8[b : b + 1], ks[b : b + 1], vi8[b : b + 1],
            vs[b : b + 1], lens[b],
        )
        assert_close(ref[b : b + 1], d)


def test_paged_kernel_interpret_matches_ref(paged_kv_pool):
    q, _, pool, tbl, lens = paged_kv_pool
    ref = paged_decode_attention_ref(q, *pool, tbl, lens)
    out = paged_decode_attention(q, *pool, tbl, lens, interpret=True)
    assert_close(out, ref)


def test_paged_op_fallback_dispatch(paged_kv_pool):
    """interpret=None off-TPU routes to the gather reference."""
    q, _, pool, tbl, lens = paged_kv_pool
    out = paged_decode_attention(q, *pool, tbl, lens)
    assert_close(out, paged_decode_attention_ref(q, *pool, tbl, lens))
    with pytest.raises(ValueError, match="one query token"):
        paged_decode_attention(jnp.concatenate([q, q], axis=2), *pool, tbl, lens)


# ---------------------------------------------------------------------------
# ContinuousBatchingEngine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def skewed_trace(served_model):
    cfg, _ = served_model
    return [
        Request(0, _prompt(cfg, 0, 6), max_new_tokens=4),
        Request(1, _prompt(cfg, 1, 7), max_new_tokens=12),
        Request(2, _prompt(cfg, 2, 8), max_new_tokens=6, arrival=2),
        Request(3, _prompt(cfg, 3, 9), max_new_tokens=3, arrival=5),
        Request(4, _prompt(cfg, 4, 6), max_new_tokens=8, arrival=5),
    ]


def test_continuous_greedy_pinned_per_request(served_model, skewed_trace):
    """Every request — including the ones admitted mid-flight into slots
    freed by retirement — reproduces a per-request ServeEngine run
    token-for-token."""
    cfg, params = served_model
    eng = ContinuousBatchingEngine(cfg, params, num_slots=2, page_size=4, num_pages=32)
    outs, stats = eng.serve(skewed_trace)
    assert set(outs) == {r.rid for r in skewed_trace}
    ref_eng = ServeEngine(cfg, params, max_len=None, page_size=4)
    for r in skewed_trace:
        ref = ref_eng.generate(jnp.asarray(r.tokens)[None], max_new_tokens=r.max_new_tokens)
        got = outs[r.rid]
        assert got.finish_reason == "length"
        assert np.array_equal(got.tokens, np.asarray(ref.tokens[0, len(r.tokens):])), r.rid
        np.testing.assert_allclose(
            got.logprobs, np.asarray(ref.logprobs[0]), rtol=1e-4, atol=1e-4
        )
    # mid-flight refill actually happened: 5 requests through 2 slots
    assert stats.admitted == 5 and stats.num_slots == 2
    # and it saves dispatches over draining slot-table-sized static batches
    assert stats.decode_dispatches < 4 + 12 + 6 + 8
    assert 0.0 < stats.slot_utilization <= 1.0


def test_continuous_retirement_frees_pages(served_model):
    cfg, params = served_model
    eng = ContinuousBatchingEngine(cfg, params, num_slots=2, page_size=4, num_pages=16)
    reqs = [
        Request(0, _prompt(cfg, 20, 6), max_new_tokens=2),
        Request(1, _prompt(cfg, 21, 6), max_new_tokens=4),
        # needs pages that only exist once request 0 and 1 retire
        Request(2, _prompt(cfg, 22, 20), max_new_tokens=8, arrival=1),
    ]
    outs, stats = eng.serve(reqs)
    assert set(outs) == {0, 1, 2}
    ref = ServeEngine(cfg, params, max_len=None, page_size=4).generate(
        jnp.asarray(reqs[2].tokens)[None], max_new_tokens=8
    )
    assert np.array_equal(outs[2].tokens, np.asarray(ref.tokens[0, 20:]))
    # peak residency stayed within the (tiny) pool
    assert stats.peak_resident_kv_bytes <= (16 - 1) * page_bytes(cfg, 4)


def test_continuous_validates(served_model):
    cfg, params = served_model
    with pytest.raises(ValueError, match="attention"):
        ContinuousBatchingEngine(reduce_config(get_arch("falcon-mamba-7b")), params)
    eng = ContinuousBatchingEngine(cfg, params, num_slots=1, page_size=4, num_pages=4)
    with pytest.raises(ValueError, match="pages"):
        eng.serve([Request(0, _prompt(cfg, 30, 30), max_new_tokens=30)])
    with pytest.raises(ValueError, match="duplicate"):
        eng.serve([
            Request(0, _prompt(cfg, 31, 4), max_new_tokens=2),
            Request(0, _prompt(cfg, 32, 4), max_new_tokens=2),
        ])
    outs, st = eng.serve([])
    assert outs == {} and st.decode_dispatches == 0


def test_continuous_sliding_window_prompt_longer_than_window(served_model):
    """SWA regression: prefill's ring-buffered cache must be un-permuted
    into the page chain, so prompts LONGER than the window stay pinned
    against the static engine (which serves the same ring buffer)."""
    cfg = reduce_config(get_arch("mixtral-8x22b"))
    assert cfg.sliding_window and cfg.sliding_window < 40
    params, _ = M.init_params(cfg, KEY)
    reqs = [
        Request(0, _prompt(cfg, 80, 40), max_new_tokens=6),  # prompt > window
        Request(1, _prompt(cfg, 81, 12), max_new_tokens=8),  # prompt < window
    ]
    outs, _ = ContinuousBatchingEngine(
        cfg, params, num_slots=2, page_size=8, num_pages=32
    ).serve(reqs)
    ref_eng = ServeEngine(cfg, params, max_len=None, page_size=8)
    for r in reqs:
        ref = ref_eng.generate(jnp.asarray(r.tokens)[None], max_new_tokens=r.max_new_tokens)
        assert np.array_equal(
            outs[r.rid].tokens, np.asarray(ref.tokens[0, len(r.tokens):])
        ), r.rid


def test_bucketed_chunked_prefill_greedy_parity(served_model):
    """The tentpole contract: admission through the bucketed planner —
    padded buckets, packed short prompts, chunked long prompts, mid-flight
    admissions into freed slots — changes NOTHING about greedy outputs.
    Every request matches a per-request ServeEngine run token-for-token,
    and after AOT warmup the whole run compiles zero programs at traffic
    time (|buckets| + chunk + decode, nothing else)."""
    cfg, params = served_model
    buckets = (8, 16)
    eng = ContinuousBatchingEngine(
        cfg, params, num_slots=2, page_size=4, num_pages=64,
        prefill_buckets=buckets, chunk_size=8, max_pack=2,
    )
    assert eng.warmup() == len(buckets) + 2
    reqs = [
        Request(0, _prompt(cfg, 60, 6), max_new_tokens=5),
        Request(1, _prompt(cfg, 61, 13), max_new_tokens=4),
        Request(2, _prompt(cfg, 62, 40), max_new_tokens=6),  # 5 chunks of 8
        Request(3, _prompt(cfg, 63, 3), max_new_tokens=5, arrival=2),  # mid-flight
        Request(4, _prompt(cfg, 64, 5), max_new_tokens=4),
    ]
    outs, stats = eng.serve(reqs)
    assert stats.admitted == 5 and stats.chunk_dispatches == 5
    cc = eng.compile_counts()
    assert cc["jit_fallback"] == 0 and cc["aot"] == len(buckets) + 2
    ref = ServeEngine(cfg, params, max_len=None, page_size=4)
    for r in reqs:
        res = ref.generate(jnp.asarray(r.tokens)[None], max_new_tokens=r.max_new_tokens)
        assert np.array_equal(
            outs[r.rid].tokens, np.asarray(res.tokens[0, len(r.tokens):])
        ), r.rid
        assert outs[r.rid].queue_wait_steps >= 0
        assert np.isfinite(outs[r.rid].ttft_wall_s)


def test_swa_prompt_spanning_chunk_boundary():
    """Chunked prefill must reproduce the sliding-window math exactly when a
    prompt longer than the window streams in across chunk boundaries (each
    chunk re-reads the paged prefix, including tokens the window has slid
    past). Dense FFN + window: capacity-MoE routing is dispatch-width-
    dependent by construction (``moe_block`` computes expert capacity per
    dispatch), so chunk-vs-one-shot bit-parity is only defined for dense
    families — see serve/README.md."""
    from dataclasses import replace

    cfg = replace(reduce_config(get_arch("smollm-135m")), sliding_window=16)
    params, _ = M.init_params(cfg, KEY)
    eng = ContinuousBatchingEngine(
        cfg, params, num_slots=2, page_size=8, num_pages=32,
        prefill_buckets=(8, 16), chunk_size=8,
    )
    reqs = [
        Request(0, _prompt(cfg, 82, 40), max_new_tokens=6),  # prompt > window
        Request(1, _prompt(cfg, 83, 12), max_new_tokens=8),
    ]
    outs, stats = eng.serve(reqs)
    assert stats.chunk_dispatches == 5  # the 40-token prompt, 8 at a time
    ref_eng = ServeEngine(cfg, params, max_len=None, page_size=8)
    for r in reqs:
        ref = ref_eng.generate(jnp.asarray(r.tokens)[None], max_new_tokens=r.max_new_tokens)
        assert np.array_equal(
            outs[r.rid].tokens, np.asarray(ref.tokens[0, len(r.tokens):])
        ), r.rid


def test_packed_admission_burst(served_model):
    """A burst of short prompts arriving together shares bucket dispatches
    (segment-masked packing) instead of serializing one prefill each — and
    still matches per-request ServeEngine outputs."""
    cfg, params = served_model
    eng = ContinuousBatchingEngine(
        cfg, params, num_slots=4, page_size=4, num_pages=64,
        prefill_buckets=(16, 32), max_pack=4,
    )
    reqs = [Request(i, _prompt(cfg, 70 + i, 3 + i), max_new_tokens=4) for i in range(4)]
    outs, stats = eng.serve(reqs)
    assert stats.admitted == 4
    assert stats.prefill_dispatches < 4  # the burst actually packed
    ref = ServeEngine(cfg, params, max_len=None, page_size=4)
    for r in reqs:
        res = ref.generate(jnp.asarray(r.tokens)[None], max_new_tokens=r.max_new_tokens)
        assert np.array_equal(
            outs[r.rid].tokens, np.asarray(res.tokens[0, len(r.tokens):])
        ), r.rid


def test_serve_engine_bucketed_prefill_program_count(served_model):
    """Distinct prompt lengths within one ladder rung share ONE compiled
    prefill program (the RCP001:serve.prefill:prompt_len fix)."""
    cfg, params = served_model
    eng = ServeEngine(cfg, params, max_len=64)
    for plen in (3, 5, 9, 20):
        eng.generate(jnp.asarray(_prompt(cfg, 95 + plen, plen))[None], max_new_tokens=2)
    assert eng._prefill_len._cache_size() == 1


def test_continuous_eos_on_last_budgeted_token_reports_eos(served_model):
    """A request whose final budgeted token IS the EOS retires via the EOS
    check on the device — finish_reason must say so."""
    cfg, params = served_model
    prompt = _prompt(cfg, 90, 8)
    eng = ContinuousBatchingEngine(cfg, params, num_slots=1, page_size=4)
    plain, _ = eng.serve([Request(0, prompt, max_new_tokens=6)])
    eos = int(plain[0].tokens[-1])  # budget ends exactly on this token
    out, _ = eng.serve([Request(0, prompt, max_new_tokens=6)], eos_id=eos)
    first = int(np.nonzero(plain[0].tokens == eos)[0][0])
    assert out[0].finish_reason == "eos"
    assert np.array_equal(out[0].tokens, plain[0].tokens[: first + 1])


def test_continuous_faulty_chip_differs(served_model):
    cfg, params = served_model
    req = [Request(0, _prompt(cfg, 40, 8), max_new_tokens=8)]
    ctx = from_fault_map(random_fault_map(1, cfg.array_rows, cfg.array_cols, 0.3))
    healthy_out, _ = ContinuousBatchingEngine(cfg, params, healthy(), num_slots=1).serve(req)
    faulty_out, _ = ContinuousBatchingEngine(cfg, params, ctx, num_slots=1).serve(req)
    assert not np.array_equal(healthy_out[0].tokens, faulty_out[0].tokens)


# ---------------------------------------------------------------------------
# EOS semantics: static (masked) and continuous (retiring) engines agree
# ---------------------------------------------------------------------------


def test_static_eos_masks_finished_sequences(served_model):
    cfg, params = served_model
    eng = ServeEngine(cfg, params, max_len=64)
    prompts = jnp.stack([jnp.asarray(_prompt(cfg, 50 + b, 8)) for b in range(2)])
    plain = eng.generate(prompts, max_new_tokens=10)
    gen = np.asarray(plain.tokens[:, 8:])
    eos = int(gen[0, 3])  # force an early EOS for sequence 0
    out = eng.generate(prompts, max_new_tokens=10, eos_id=eos)
    got = np.asarray(out.tokens[:, 8:])
    lps = np.asarray(out.logprobs)
    for b in range(2):
        hits = np.nonzero(gen[b] == eos)[0]
        cut = int(hits[0]) if hits.size else gen.shape[1] - 1
        # identical up to and including the EOS token...
        assert np.array_equal(got[b, : cut + 1], gen[b, : cut + 1])
        # ...then pad emission with logprob exactly 0
        assert np.all(got[b, cut + 1 :] == eng.pad_id)
        assert np.all(lps[b, cut + 1 :] == 0.0)
    assert np.any(got[0, 4:] != gen[0, 4:]) or gen.shape[1] == 5


def test_static_and_continuous_agree_on_eos(served_model):
    cfg, params = served_model
    prompt = _prompt(cfg, 60, 8)
    plain = ServeEngine(cfg, params, max_len=64).generate(
        jnp.asarray(prompt)[None], max_new_tokens=12
    )
    gen = np.asarray(plain.tokens[0, 8:])
    eos = int(gen[5])
    static = ServeEngine(cfg, params, max_len=64).generate(
        jnp.asarray(prompt)[None], max_new_tokens=12, eos_id=eos
    )
    cont, _ = ContinuousBatchingEngine(cfg, params, num_slots=1, page_size=4).serve(
        [Request(0, prompt, max_new_tokens=12)], eos_id=eos
    )
    out = cont[0]
    assert out.finish_reason == "eos"
    cut = int(np.nonzero(gen == eos)[0][0])
    # continuous stops AT the EOS; static pads past it — same tokens before
    assert np.array_equal(out.tokens, np.asarray(static.tokens[0, 8 : 8 + cut + 1]))
    static_tail = np.asarray(static.tokens[0, 8 + cut + 1 :])
    assert np.all(static_tail == 0)


# ---------------------------------------------------------------------------
# ServeEngine explicit KV capacity (max_len=None)
# ---------------------------------------------------------------------------


def test_serve_engine_derives_cache_len(served_model):
    cfg, params = served_model
    eng = ServeEngine(cfg, params, max_len=None, page_size=8)
    assert eng.cache_len_for(6, 5) == 32  # 11 tokens -> bottom ladder rung
    assert eng.cache_len_for(8, 8) == 32
    assert eng.cache_len_for(200, 100) == 512  # past the top bucket: doubled rung
    unbucketed = ServeEngine(cfg, params, max_len=None, page_size=8,
                             prefill_buckets=None)
    assert unbucketed.cache_len_for(6, 5) == 16  # 11 tokens -> 2 pages
    fixed = ServeEngine(cfg, params, max_len=48)
    assert fixed.cache_len_for(6, 5) == 48
    prompts = jnp.stack([jnp.asarray(_prompt(cfg, 70 + b, 6)) for b in range(2)])
    a = eng.generate(prompts, max_new_tokens=5)
    b = fixed.generate(prompts, max_new_tokens=5)
    assert np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


# ---------------------------------------------------------------------------
# ShardedFleetServeEngine: ragged per-chip streams
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet(served_model):
    cfg, _ = served_model
    chips = []
    for i, rate in enumerate((0.0, 0.25, 0.4, 0.1)):
        params, _ = M.init_params(cfg, jax.random.PRNGKey(i))
        ctx = (
            healthy()
            if rate == 0.0
            else from_fault_map(random_fault_map(i, cfg.array_rows, cfg.array_cols, rate))
        )
        chips.append((params, ctx))
    streams = []
    for c in range(len(chips)):
        streams.append([
            Request(0, _prompt(cfg, 100 * c, 5 + c), max_new_tokens=3 + c),
            Request(1, _prompt(cfg, 100 * c + 1, 7), max_new_tokens=9 - c),
            Request(2, _prompt(cfg, 100 * c + 2, 4), max_new_tokens=5, arrival=2 + c),
        ])
    return cfg, chips, streams


def test_fleet_sharded_serve_pinned_per_chip(fleet):
    cfg, chips, streams = fleet
    eng = ShardedFleetServeEngine(
        cfg, [p for p, _ in chips], [c for _, c in chips],
        num_slots=2, page_size=4, num_pages=32,
    )
    outs, stats = eng.serve(streams)
    assert stats.decode_dispatches > 0
    for c, (params, ctx) in enumerate(chips):
        ref, _ = ContinuousBatchingEngine(
            cfg, params, ctx, num_slots=2, page_size=4, num_pages=32
        ).serve(streams[c])
        assert set(outs[c]) == set(ref)
        for rid in ref:
            assert np.array_equal(outs[c][rid].tokens, ref[rid].tokens), (c, rid)
            np.testing.assert_allclose(
                outs[c][rid].logprobs, ref[rid].logprobs, rtol=1e-4, atol=1e-4
            )
    # ragged streams: chips retire independently — the fused dispatch count
    # is bounded by the busiest chip, not the fleet-wide sum
    assert stats.decode_dispatches < sum(
        r.max_new_tokens for s in streams for r in s
    )


def test_fleet_temperature_keys_reproducible_and_independent(fleet):
    """Same fleet key -> identical tokens across runs; different chips (same
    params, same stream) -> different samples (per-chip key streams)."""
    cfg, chips, _ = fleet
    params0 = chips[0][0]
    stream = [
        Request(0, _prompt(cfg, 300, 6), max_new_tokens=8),
        Request(1, _prompt(cfg, 301, 6), max_new_tokens=8),
    ]
    eng = ShardedFleetServeEngine(
        cfg, [params0, params0], None, num_slots=2, page_size=4, num_pages=32
    )
    k = jax.random.PRNGKey(11)
    o1, _ = eng.serve([stream, stream], temperature=1.0, key=k)
    o2, _ = eng.serve([stream, stream], temperature=1.0, key=k)
    for c in range(2):
        for rid in o1[c]:
            assert np.array_equal(o1[c][rid].tokens, o2[c][rid].tokens)
    # identical chips + identical streams, but independent per-chip keys
    assert any(
        not np.array_equal(o1[0][rid].tokens, o1[1][rid].tokens) for rid in o1[0]
    )
    o3, _ = eng.serve([stream, stream], temperature=1.0, key=jax.random.PRNGKey(12))
    assert any(
        not np.array_equal(o1[0][rid].tokens, o3[0][rid].tokens) for rid in o1[0]
    )


def test_fleet_sharded_serve_validates(fleet):
    cfg, chips, streams = fleet
    with pytest.raises(ValueError, match="at least one"):
        ShardedFleetServeEngine(cfg, [])
    with pytest.raises(ValueError, match="fault contexts"):
        ShardedFleetServeEngine(cfg, [chips[0][0]], [healthy(), healthy()])
    eng = ShardedFleetServeEngine(cfg, [p for p, _ in chips[:2]], num_slots=1)
    with pytest.raises(ValueError, match="streams"):
        eng.serve([streams[0]])
