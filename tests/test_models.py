"""Per-arch smoke tests (reduced configs, CPU): forward/train-step shapes +
no NaNs, prefill/decode parity, attention-impl equivalence, fault-mask
integration at the model level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.models import model as M
from repro.models.layers import attention_impl
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()


def _batch(cfg, b=2, s=16, with_labels=True, key=KEY):
    batch = {}
    if cfg.modality == "audio":
        batch["embeds"] = jax.random.normal(key, (b, s, M.AUDIO_FRAME_DIM))
        if with_labels:
            batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        return batch
    st = s - (cfg.frontend_tokens if cfg.modality == "vision" else 0)
    if cfg.modality == "vision":
        batch["embeds"] = jax.random.normal(
            key, (b, cfg.frontend_tokens, M.VISION_PATCH_DIM)
        )
    batch["tokens"] = jax.random.randint(key, (b, st), 0, cfg.vocab_size)
    if with_labels:
        batch["labels"] = jax.random.randint(key, (b, st), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduce_config(get_arch(arch))
    params, specs = M.init_params(cfg, KEY)
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda *_: 0, params)
    )
    batch = _batch(cfg)
    fm = random_fault_map(0, cfg.array_rows, cfg.array_cols, 0.05)
    logits, aux = M.forward(params, batch, cfg, from_fault_map(fm), remat="none")
    b = batch.get("tokens", batch.get("embeds")).shape[0]
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_finite(arch):
    cfg = reduce_config(get_arch(arch))
    params, _ = M.init_params(cfg, KEY)
    ocfg = AdamWConfig(learning_rate=1e-3)
    step = make_train_step(cfg, ocfg, remat="none", moe_cf=8.0)
    opt = adamw_init(params, ocfg)
    batch = _batch(cfg)
    fm = random_fault_map(0, cfg.array_rows, cfg.array_cols, 0.05)
    params2, opt2, metrics = step(params, opt, batch, from_fault_map(fm))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree_util.tree_map(lambda a, b: a - b, params, params2),
        0.0,
    )
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if not get_arch(a).is_encoder])
def test_prefill_decode_parity(arch):
    cfg = reduce_config(get_arch(arch))
    params, _ = M.init_params(cfg, KEY)
    b, s = 2, 24
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    ft = cfg.frontend_tokens if cfg.modality == "vision" else 0
    if ft:
        batch["embeds"] = jax.random.normal(KEY, (b, ft, M.VISION_PATCH_DIM))
    ctx = from_fault_map(random_fault_map(0, cfg.array_rows, cfg.array_cols, 0.05))
    full, _ = M.forward(params, batch, cfg, ctx, remat="none", attn_impl="dense", moe_cf=16.0)
    pre = {k: (v[:, :16] if k == "tokens" else v) for k, v in batch.items()}
    lp, cache = M.prefill(params, pre, cfg, ctx, cache_len=s + ft, moe_cf=16.0)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(full[:, 15 + ft]), rtol=1e-4, atol=2e-3
    )
    for t in range(16, s):
        lg, cache = M.decode_step(params, toks[:, t : t + 1], cache, cfg, ctx, moe_cf=16.0)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t + ft]), rtol=1e-4, atol=2e-3
        )


def test_blockwise_matches_dense_attention():
    b, hq, hkv, s, d = 2, 4, 2, 128, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    for window in (None, 32):
        dense = attention_impl(q, k, v, causal=True, window=window, impl="dense")
        blk = attention_impl(
            q, k, v, causal=True, window=window, impl="blockwise"
        )
        np.testing.assert_allclose(np.asarray(blk), np.asarray(dense), rtol=1e-5, atol=1e-5)


def test_moe_scatter_matches_einsum():
    cfg = reduce_config(get_arch("mixtral-8x22b"))
    params, _ = M.init_params(cfg, KEY)
    batch = _batch(cfg, s=32)
    for ctx in (healthy(), from_fault_map(random_fault_map(0, 16, 16, 0.1))):
        le, _ = M.forward(params, batch, cfg, ctx, remat="none", moe_impl="einsum", moe_cf=8.0)
        ls, _ = M.forward(params, batch, cfg, ctx, remat="none", moe_impl="scatter", moe_cf=8.0)
        np.testing.assert_allclose(np.asarray(le), np.asarray(ls), rtol=1e-4, atol=2e-3)


def test_fault_mask_changes_output_and_healthy_does_not():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    batch = _batch(cfg, with_labels=False)
    base, _ = M.forward(params, batch, cfg, healthy(), remat="none")
    fm = random_fault_map(0, cfg.array_rows, cfg.array_cols, 0.2)
    faulty, _ = M.forward(params, batch, cfg, from_fault_map(fm), remat="none")
    assert float(jnp.max(jnp.abs(base - faulty))) > 1e-3
    zero = random_fault_map(0, cfg.array_rows, cfg.array_cols, 0.0)
    same, _ = M.forward(params, batch, cfg, from_fault_map(zero), remat="none")
    np.testing.assert_allclose(np.asarray(base), np.asarray(same), rtol=1e-6, atol=1e-6)


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    ocfg = AdamWConfig(learning_rate=1e-3)
    opt = adamw_init(params, ocfg)
    batch = _batch(cfg, b=4)
    step1 = make_train_step(cfg, ocfg, remat="none", microbatches=1)
    step4 = make_train_step(cfg, ocfg, remat="none", microbatches=4)
    p1, _, m1 = step1(params, opt, batch, healthy())
    p4, _, m4 = step4(params, opt, batch, healthy())
    # same gradient (up to accumulation order) -> nearly identical update
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4
    )
    assert max(jax.tree_util.tree_leaves(diff)) < 5e-5


def test_remat_policies_agree():
    cfg = reduce_config(get_arch("qwen3-0.6b"))
    params, _ = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    outs = []
    for remat in ("none", "dots", "full"):
        loss, _ = M.loss_fn(params, batch, cfg, healthy(), remat=remat)
        outs.append(float(loss))
    assert max(outs) - min(outs) < 1e-5
