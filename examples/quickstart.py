"""Quickstart: fault-aware training (FAT) of a small LM for one faulty chip.

1. Pre-train a (reduced) smollm on the synthetic token stream — the
   'user-provided pre-trained DNN' of the paper's pipeline.
2. Inject a permanent-fault map into the accelerator's systolic array.
3. Observe the accuracy drop, run FAT, observe recovery.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.data.synthetic import TokenStream
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_eval_step, make_train_step


def main():
    cfg = reduce_config(get_arch("smollm-135m"))
    print(f"arch: {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model})")
    print(f"systolic array: {cfg.array_rows}x{cfg.array_cols}")

    stream = TokenStream(cfg.vocab_size, seq_len=32, batch_size=8, seed=1, noise=0.02)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(learning_rate=3e-3)
    train = jax.jit(make_train_step(cfg, ocfg, remat="none"))
    evaluate = jax.jit(make_eval_step(cfg, remat="none"))
    eval_batch = stream.batch_at(10_000_000)

    # 1) pre-train healthy
    opt = adamw_init(params, ocfg)
    t0 = time.time()
    for i in range(150):
        params, opt, m = train(params, opt, stream.batch_at(i), healthy())
    acc0 = float(evaluate(params, eval_batch, healthy())["accuracy"])
    print(f"[pretrain] acc={acc0:.3f}  ({time.time()-t0:.1f}s)")

    # 2) a chip comes back from the fab with permanent faults
    fm = random_fault_map(7, cfg.array_rows, cfg.array_cols, fault_rate=0.25, chip_id="chip-7")
    ctx = from_fault_map(fm)
    acc_f = float(evaluate(params, eval_batch, ctx)["accuracy"])
    print(f"[faulty  ] chip {fm.chip_id}: rate={fm.fault_rate:.2f} acc={acc_f:.3f} "
          f"(drop {acc0-acc_f:+.3f})")

    # 3) FAT: retrain WITH the fault mask applied
    opt = adamw_init(params, ocfg)
    for i in range(80):
        params, opt, m = train(params, opt, stream.batch_at(1000 + i), ctx)
    acc_fat = float(evaluate(params, eval_batch, ctx)["accuracy"])
    print(f"[FAT     ] acc={acc_fat:.3f} (recovered {acc_fat-acc_f:+.3f})")


if __name__ == "__main__":
    main()
