"""End-to-end eFAT fleet retraining (the paper's headline experiment,
SIV-C / Fig. 13): tune one pre-trained DNN for 100 faulty chips.

Pipeline (paper Fig. 7): resilience analysis (Step 1, Algo 1 rates) ->
per-chip retraining amounts (Step 2) -> resilience-driven grouping & fusion
(Step 3, Algo 2) -> consolidated FAT + per-chip evaluation (Step 4).
Compared against: individual (no fusion), fixed-policy [8], random pairwise
merging (TRE-map [16]).

    PYTHONPATH=src python examples/fleet_retraining.py [--chips 100]
"""
import argparse
import time

import numpy as np

from repro.configs import get_arch
from repro.core import EFAT, EFATConfig, correlated_family, gaussian_chip_rates, random_fault_map
from repro.train.fat_trainer import ClassifierFATTrainer


def make_fleet(n_chips: int, correlated: bool, seed: int = 0):
    """Paper SIV-C: rates ~ N(0.1, 0.02). 'correlated' adds shared wafer
    defects (the regime where Step-3 fusion pays off — Eq. 3)."""
    if correlated:
        return correlated_family(
            seed, n_chips, 32, 32, base_rate=0.07, idio_rate=0.025, chip_prefix="chip"
        )
    rng = np.random.default_rng(seed)
    rates = gaussian_chip_rates(rng, n_chips, mean=0.1, sigma=0.02)
    return [
        random_fault_map(rng, 32, 32, float(r), chip_id=f"chip{i}")
        for i, r in enumerate(rates)
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=100)
    ap.add_argument("--independent", action="store_true",
                    help="i.i.d. fault maps (fusion should find ~no pairs)")
    args = ap.parse_args()

    print("=== eFAT fleet retraining ===")
    t0 = time.time()
    trainer = ClassifierFATTrainer(get_arch("paper-mlp"), pretrain_steps=600, eval_batches=4)
    constraint = trainer.baseline_accuracy - 0.03
    print(f"pretrained acc={trainer.baseline_accuracy:.3f}; constraint={constraint:.3f} "
          f"({time.time()-t0:.0f}s)")

    fleet = make_fleet(args.chips, correlated=not args.independent)
    rates = [fm.fault_rate for fm in fleet]
    print(f"fleet: {len(fleet)} chips, rates {min(rates):.3f}..{max(rates):.3f}")

    ef = EFAT(
        trainer,
        EFATConfig(
            constraint=constraint, max_fr=0.35, max_interval=0.05, step_ratio=0.6,
            repeats=5, max_steps=400, m_comparisons=8, k_iterations=2, stat="max",
        ),
    )
    t0 = time.time()
    ef.build_resilience_table(fleet)
    print(f"\n[Step 1] resilience map ({time.time()-t0:.0f}s):")
    t = ef.table
    for r, mx in zip(t.rates, t.max_steps_stat):
        print(f"   rate={r:.3f} -> steps(max)={mx:.0f}")

    results = {}
    t0 = time.time()
    results["eFAT"] = ef.run(fleet)
    for method, kw in (("individual", {}), ("fixed", dict(steps_per_chip=80)),
                       ("random-merge", {})):
        results[method] = ef.run_baseline(fleet, method, **kw)

    print(f"\n=== comparison (paper Fig. 13) [{time.time()-t0:.0f}s] ===")
    print(f"{'method':14s} {'jobs':>5s} {'total_steps':>12s} {'steps/chip':>11s} {'satisfied':>10s}")
    for name, r in results.items():
        s = r.summary()
        print(
            f"{name:14s} {s['jobs']:5d} {s['total_steps']:12.0f} "
            f"{s['mean_steps_per_chip']:11.1f} {s['satisfied_fraction']:9.0%}"
        )

    # fleet scheduling (repro.fleet): how the eFAT plan's jobs were packed
    # into population chunks, and the vectorized lane-steps LPT saved vs
    # submitting in arrival order
    sched = results["eFAT"].scheduling
    if sched is not None:
        print(
            f"\nscheduler ({sched['policy']}, chunks of {sched['population_size']}): "
            f"{sched['jobs']} jobs -> {sched['chunks']} chunks, "
            f"wasted lane-steps {sched['wasted_steps']:.0f} "
            f"(arrival order: {sched['arrival_wasted_steps']:.0f}, "
            f"saved {sched['wasted_steps_reduction']:.0f})"
        )


if __name__ == "__main__":
    main()
