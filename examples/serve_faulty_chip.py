"""Serve a faulty chip's fault-aware model with continuous batching.

Shows the deployment half of the eFAT story as a *request stream*, the way
a serving chip actually sees traffic: requests with mixed prompt lengths,
mixed generation budgets and staggered arrival times flow through a
continuous-batching engine (paged KV cache + slot table) on the chip they
were tuned for — and the static rectangular-batch engine is run on the same
requests for comparison, pinning tokens and counting the dispatches and KV
bytes it burns past each request's own budget.

    PYTHONPATH=src python examples/serve_faulty_chip.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.core.masking import mask_params
from repro.data.synthetic import TokenStream
from repro.models import model as M
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine, dense_kv_bytes
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_eval_step, make_train_step


def main():
    cfg = reduce_config(get_arch("qwen3-0.6b"))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=2, noise=0.02)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(learning_rate=3e-3)
    train = jax.jit(make_train_step(cfg, ocfg, remat="none"))
    evaluate = jax.jit(make_eval_step(cfg, remat="none"))

    opt = adamw_init(params, ocfg)
    for i in range(120):
        params, opt, _ = train(params, opt, stream.batch_at(i), healthy())

    fm = random_fault_map(3, cfg.array_rows, cfg.array_cols, 0.2, chip_id="edge-3")
    ctx = from_fault_map(fm)
    # FAT for this chip, then ship FAP-masked weights
    opt = adamw_init(params, ocfg)
    for i in range(60):
        params, opt, _ = train(params, opt, stream.batch_at(500 + i), ctx)
    shipped = mask_params(params, ctx)

    eval_batch = stream.batch_at(10_000_001)
    acc = float(evaluate(shipped, eval_batch, ctx)["accuracy"])
    print(f"chip {fm.chip_id}: fault rate {fm.fault_rate:.2f}, deployed acc {acc:.3f}")

    # --- the request stream: mixed lengths, mixed budgets, staggered arrivals
    tok = lambda i, n: np.asarray(stream.batch_at(40 + i)["tokens"][0, :n])
    requests = [
        Request(0, tok(0, 16), max_new_tokens=4),
        Request(1, tok(1, 12), max_new_tokens=24),
        Request(2, tok(2, 8), max_new_tokens=6, arrival=2),
        Request(3, tok(3, 20), max_new_tokens=8, arrival=4),
        Request(4, tok(4, 6), max_new_tokens=16, arrival=4),
        Request(5, tok(5, 10), max_new_tokens=5, arrival=9),
    ]

    engine = ContinuousBatchingEngine(
        cfg, shipped, ctx, num_slots=2, page_size=8, num_pages=64
    )
    t0 = time.time()
    outs, stats = engine.serve(requests)
    dt = time.time() - t0
    print(
        f"continuous: {stats.emitted_tokens} tokens over {len(requests)} requests "
        f"in {stats.decode_dispatches} decode dispatches "
        f"({stats.emitted_tokens / dt:.0f} tok/s, "
        f"slot utilization {stats.slot_utilization:.0%}, "
        f"peak KV {stats.peak_resident_kv_bytes} B)"
    )
    for r in requests:
        o = outs[r.rid]
        print(
            f"  rid {r.rid}: prompt {len(r.tokens)} "
            f"arrival {r.arrival} ttft {o.ttft} finished@{o.finished_step} "
            f"({o.finish_reason}) -> {o.tokens[:8].tolist()}{'...' if len(o.tokens) > 8 else ''}"
        )

    # --- static engine on the same requests: one padded-horizon batch per
    # prompt length (rectangular batches can't mix lengths), pinned tokens
    static = ServeEngine(cfg, shipped, ctx, max_len=None, page_size=8)
    dispatches = 0
    kv = 0
    for r in requests:
        ref = static.generate(
            jnp.asarray(r.tokens)[None], max_new_tokens=r.max_new_tokens
        )
        assert np.array_equal(outs[r.rid].tokens, np.asarray(ref.tokens[0, len(r.tokens):]))
        dispatches += r.max_new_tokens
        kv = max(kv, dense_kv_bytes(cfg, 1, static.cache_len_for(len(r.tokens), r.max_new_tokens)))
    print(
        f"static (per-request, tokens pinned): {dispatches} dispatches vs "
        f"{stats.decode_dispatches} continuous — continuous packs "
        f"{len(requests)} ragged requests into 2 slots with identical outputs"
    )


if __name__ == "__main__":
    main()
