"""Serve a fault-aware model ON the faulty chip it was tuned for.

Shows the deployment half of the eFAT story: the shipped artifact is the
FAP-masked weight set; at serving time the chip's own fault map is applied
(a no-op on the already-masked weights) and batched generation runs through
prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_faulty_chip.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.core.masking import mask_params
from repro.data.synthetic import TokenStream
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_eval_step, make_train_step


def main():
    cfg = reduce_config(get_arch("qwen3-0.6b"))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=2, noise=0.02)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(learning_rate=3e-3)
    train = jax.jit(make_train_step(cfg, ocfg, remat="none"))
    evaluate = jax.jit(make_eval_step(cfg, remat="none"))

    opt = adamw_init(params, ocfg)
    for i in range(120):
        params, opt, _ = train(params, opt, stream.batch_at(i), healthy())

    fm = random_fault_map(3, cfg.array_rows, cfg.array_cols, 0.2, chip_id="edge-3")
    ctx = from_fault_map(fm)
    # FAT for this chip, then ship FAP-masked weights
    opt = adamw_init(params, ocfg)
    for i in range(60):
        params, opt, _ = train(params, opt, stream.batch_at(500 + i), ctx)
    shipped = mask_params(params, ctx)

    eval_batch = stream.batch_at(10_000_001)
    acc = float(evaluate(shipped, eval_batch, ctx)["accuracy"])
    print(f"chip {fm.chip_id}: fault rate {fm.fault_rate:.2f}, deployed acc {acc:.3f}")

    engine = ServeEngine(cfg, shipped, ctx, max_len=64)
    prompts = stream.batch_at(42)["tokens"][:4, :16]
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=16)
    dt = time.time() - t0
    print(f"generated {out.tokens.shape[0]}x16 tokens in {dt:.2f}s "
          f"({out.tokens.shape[0]*16/dt:.0f} tok/s on CPU)")
    print("sample continuation:", out.tokens[0, 16:].tolist())
    print("mean logprob:", float(jnp.mean(out.logprobs)))


if __name__ == "__main__":
    main()
