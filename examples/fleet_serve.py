"""Serve a whole fleet of faulty chips' deployed models in ONE program.

The deployment half of eFAT at fleet scale, as *request streams*: each chip
runs the fault-aware weights its retraining job shipped, under its own
fault map, and consumes its OWN ragged stream of requests (mixed prompt
lengths, mixed budgets, staggered arrivals) through its own
continuous-batch slot table over a paged KV cache. One
``shard_map``-over-the-pop-mesh dispatch advances every chip's in-flight
slots a token (``ShardedFleetServeEngine``), so no chip waits on another
chip's traffic — and greedy decoding still reproduces a per-chip
``ContinuousBatchingEngine`` token-for-token.

Force a multi-device CPU mesh to see real sharding:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/fleet_serve.py [--chips 4] \
        [--trace-out fleet.trace.json] [--metrics-out fleet.jsonl]

``--trace-out`` writes a Chrome trace of the fleet run — one Perfetto
swimlane per chip slot plus per-chip page-pool counters; ``--metrics-out``
writes the JSONL event+metrics log (``python -m repro.launch.obs`` converts
or summarizes it). ``--probe-every N`` turns on the online fault-detection
stack (per-chip ABFT checksum/canary probes + health scoring + alerts)
and ``--health-out`` saves the per-chip health summary JSON.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.core.masking import mask_params
from repro.data.synthetic import TokenStream
from repro.fleet import ShardedFleetServeEngine
from repro.models import model as M
from repro.serve import ContinuousBatchingEngine, Request
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the fleet run's Chrome trace")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the fleet run's JSONL event+metrics log")
    ap.add_argument("--probe-every", type=int, default=None, metavar="N",
                    help="dispatch per-chip ABFT probes every N fused decode "
                         "dispatches and score chip health")
    ap.add_argument("--health-out", default=None, metavar="FILE",
                    help="write the per-chip health + alert summary JSON "
                         "(needs --probe-every)")
    args = ap.parse_args()
    if args.health_out and not args.probe_every:
        ap.error("--health-out needs --probe-every")

    cfg = reduce_config(get_arch("qwen3-0.6b"))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=2, noise=0.02)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(learning_rate=3e-3)
    train = jax.jit(make_train_step(cfg, ocfg, remat="none"))

    opt = adamw_init(params, ocfg)
    for i in range(100):
        params, opt, _ = train(params, opt, stream.batch_at(i), healthy())

    # one quick FAT pass per chip, shipping FAP-masked weights (chip 0 stays
    # healthy to show mixed fleets)
    chips = []
    for c in range(args.chips):
        if c == 0:
            chips.append((params, healthy(), 0.0))
            continue
        fm = random_fault_map(c, cfg.array_rows, cfg.array_cols, 0.1 + 0.05 * c,
                              chip_id=f"edge-{c}")
        ctx = from_fault_map(fm)
        p, o = params, adamw_init(params, ocfg)
        for i in range(30):
            p, o, _ = train(p, o, stream.batch_at(500 + i), ctx)
        chips.append((mask_params(p, ctx), ctx, fm.fault_rate))

    # each chip gets its OWN traffic: different lengths, budgets, arrivals
    def stream_for(c: int) -> list[Request]:
        tok = lambda i, n: np.asarray(stream.batch_at(60 + 10 * c + i)["tokens"][0, :n])
        return [
            Request(0, tok(0, 8 + 2 * c), max_new_tokens=4 + 3 * c),
            Request(1, tok(1, 12), max_new_tokens=16 - 2 * c),
            Request(2, tok(2, 6), max_new_tokens=6, arrival=2 + c),
            Request(3, tok(3, 10), max_new_tokens=8, arrival=4),
        ]

    streams = [stream_for(c) for c in range(args.chips)]

    rec = None
    if args.trace_out or args.metrics_out or args.health_out:
        from repro.obs import Recorder

        rec = Recorder()
    alert_rules = None
    if args.probe_every:
        from repro.obs import default_slo_rules

        alert_rules = default_slo_rules()
    t0 = time.time()
    fleet_eng = ShardedFleetServeEngine(
        cfg, [p for p, _, _ in chips], [c for _, c, _ in chips],
        num_slots=2, page_size=8, num_pages=64, recorder=rec,
        probe_every=args.probe_every, alert_rules=alert_rules,
    )
    outs, stats = fleet_eng.serve(streams)
    t_fleet = time.time() - t0
    print(
        f"fleet engine: {len(chips)} chips (pop mesh extent "
        f"{int(fleet_eng.mesh.shape['pop'])}) served "
        f"{stats.emitted_tokens} tokens across {stats.admitted} ragged requests "
        f"in {stats.decode_dispatches} fused dispatches / {t_fleet:.2f}s "
        f"(slot utilization {stats.slot_utilization:.0%})"
    )

    t0 = time.time()
    per_chip_dispatches = 0
    for c, (p, ctx, _) in enumerate(chips):
        ref, ref_stats = ContinuousBatchingEngine(
            cfg, p, ctx, num_slots=2, page_size=8, num_pages=64
        ).serve(streams[c])
        per_chip_dispatches += ref_stats.decode_dispatches
        for rid, out in ref.items():
            assert np.array_equal(outs[c][rid].tokens, out.tokens), (c, rid)
    t_serial = time.time() - t0
    print(
        f"per-chip engines (reference): {per_chip_dispatches} dispatches / "
        f"{t_serial:.2f}s — fleet output matches token-for-token; "
        f"{per_chip_dispatches / stats.decode_dispatches:.2f}x dispatch amortization"
    )

    for c, (_, _, rate) in enumerate(chips):
        o = outs[c]
        lead = o[0]
        health = (
            f" health={fleet_eng.health.state(c)}"
            if fleet_eng.health is not None else ""
        )
        print(
            f"  chip {c}: fault_rate={rate:.2f} requests={len(o)} "
            f"ttft(rid0)={lead.ttft} continuation={lead.tokens.tolist()}{health}"
        )
    if args.probe_every:
        print(
            f"probes: {stats.probe_dispatches} dispatches "
            f"(every {args.probe_every} fused steps), detections="
            f"{fleet_eng.health.detections}, alerts firing="
            f"{fleet_eng.alerts.firing() if fleet_eng.alerts else []}"
        )
    if args.health_out:
        import json

        with open(args.health_out, "w") as f:
            json.dump(dict(
                health=fleet_eng.health.summary(),
                alerts=fleet_eng.alerts.summary() if fleet_eng.alerts else None,
            ), f, indent=2)
        print(f"health: {args.health_out}")

    if args.trace_out:
        from repro.obs import write_chrome_trace

        tr = write_chrome_trace(args.trace_out, rec)
        print(f"trace: {args.trace_out} ({len(tr['traceEvents'])} events — "
              f"one Perfetto lane per chip slot)")
    if args.metrics_out:
        from repro.obs import write_jsonl

        write_jsonl(args.metrics_out, rec)
        print(f"metrics: {args.metrics_out} ({len(rec.event_list())} events, "
              f"recorder self time {rec.self_time_s*1e3:.2f} ms)")


if __name__ == "__main__":
    main()
