"""Serve a whole fleet of faulty chips' deployed models in ONE program.

The deployment half of eFAT at fleet scale: each chip runs the fault-aware
weights its retraining job shipped, under its own fault map. Per-chip
``ServeEngine`` loops cost N Python generate loops; ``FleetServeEngine``
(repro.fleet) stacks the N (params, FaultContext) pairs and vmaps the fused
sampling+decode step over the chip axis, so the entire fleet advances one
token per dispatch — and greedy decoding reproduces every per-chip engine
token-for-token.

    PYTHONPATH=src python examples/fleet_serve.py [--chips 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.core.masking import mask_params
from repro.data.synthetic import TokenStream
from repro.fleet import FleetServeEngine
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduce_config(get_arch("qwen3-0.6b"))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=2, noise=0.02)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(learning_rate=3e-3)
    train = jax.jit(make_train_step(cfg, ocfg, remat="none"))

    opt = adamw_init(params, ocfg)
    for i in range(100):
        params, opt, _ = train(params, opt, stream.batch_at(i), healthy())

    # one quick FAT pass per chip, shipping FAP-masked weights (chip 0 stays
    # healthy to show mixed fleets)
    chips = []
    for c in range(args.chips):
        if c == 0:
            chips.append((params, healthy(), 0.0))
            continue
        fm = random_fault_map(c, cfg.array_rows, cfg.array_cols, 0.1 + 0.05 * c,
                              chip_id=f"edge-{c}")
        ctx = from_fault_map(fm)
        p, o = params, adamw_init(params, ocfg)
        for i in range(30):
            p, o, _ = train(p, o, stream.batch_at(500 + i), ctx)
        chips.append((mask_params(p, ctx), ctx, fm.fault_rate))

    prompts = stream.batch_at(42)["tokens"][:4, :16]

    t0 = time.time()
    fleet_eng = FleetServeEngine(
        cfg, [p for p, _, _ in chips], [c for _, c, _ in chips], max_len=64
    )
    out = fleet_eng.generate(prompts, max_new_tokens=args.tokens)
    t_fleet = time.time() - t0
    n_tok = out.tokens.shape[0] * out.tokens.shape[1] * args.tokens
    print(f"fleet engine: {len(chips)} chips x {prompts.shape[0]} prompts x "
          f"{args.tokens} tokens in {t_fleet:.2f}s ({n_tok / t_fleet:.0f} tok/s)")

    t0 = time.time()
    for i, (p, ctx, _) in enumerate(chips):
        ref = ServeEngine(cfg, p, ctx, max_len=64).generate(
            prompts, max_new_tokens=args.tokens
        )
        toks_i, _ = out.chip(i)
        assert np.array_equal(np.asarray(toks_i), np.asarray(ref.tokens)), f"chip {i}"
    t_serial = time.time() - t0
    print(f"per-chip engines (reference): {t_serial:.2f}s — fleet output matches "
          f"token-for-token; {t_serial / t_fleet:.2f}x amortization")

    for i, (_, _, rate) in enumerate(chips):
        print(f"  chip {i}: fault_rate={rate:.2f} "
              f"mean_logprob={float(out.logprobs[i].mean()):.3f} "
              f"continuation={out.tokens[i, 0, 16:].tolist()}")


if __name__ == "__main__":
    main()
